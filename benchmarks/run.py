# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,table4,...]
    PYTHONPATH=src python -m benchmarks.run --smoke   # <60s; BENCH_smoke.json

Each module reproduces one paper artifact (DESIGN.md §8).  `--full` uses the
larger graph sizes; default (quick) finishes on one CPU in minutes.
`--smoke` runs one tiny fig7 cell and writes `BENCH_smoke.json` — the CI
benchmark-smoke job uploads it so the perf trajectory accumulates per commit.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (
    fig10_breakdown,
    fig12_sensitivity,
    fig2_edge_volume,
    fig7_response_time,
    fig8_access_volume,
    roofline,
    table4_accuracy,
    table5_degree,
    table6_memory,
)
from benchmarks.common import emit

MODULES = {
    "fig2": fig2_edge_volume,
    "table4": table4_accuracy,
    "fig7": fig7_response_time,
    "fig8": fig8_access_volume,
    "fig10": fig10_breakdown,
    "table5": table5_degree,
    "table6": table6_memory,
    "fig12": fig12_sensitivity,
    "roofline": roofline,
}


def smoke() -> None:
    from benchmarks.common import ROWS

    t0 = time.time()
    fig7_response_time.smoke()
    wall = time.time() - t0
    out = {"rows": list(ROWS), "wall_s": round(wall, 2)}
    with open("BENCH_smoke.json", "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote BENCH_smoke.json ({wall:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny fig7 cell, <60s; writes BENCH_smoke.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    names = [s for s in args.only.split(",") if s] or list(MODULES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            MODULES[name].run(quick=not args.full)
            emit(f"{name}/_module_wall_s", (time.time() - t0) * 1e6, "ok")
        except Exception as e:  # noqa
            traceback.print_exc()
            emit(f"{name}/_module_wall_s", (time.time() - t0) * 1e6, f"FAILED:{e}")
            sys.exit(1) if False else None


if __name__ == '__main__':
    main()
