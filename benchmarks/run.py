# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,table4,...]
    PYTHONPATH=src python -m benchmarks.run --smoke   # <60s; BENCH_smoke.json
    PYTHONPATH=src python -m benchmarks.run --smoke --devices 8
                                            # sharded smoke; BENCH_sharded.json

Each module reproduces one paper artifact (DESIGN.md §8).  `--full` uses the
larger graph sizes; default (quick) finishes on one CPU in minutes.
`--smoke` runs the tiny fig7 cells (including the serving-frontend read
cell, ISSUE 6) and writes `BENCH_smoke.json` — the CI benchmark-smoke job
gates on it (benchmarks/check_regression.py).  All stream cells emit
through `StreamStats.as_dict()` (`benchmarks.common.emit_stream_stats`),
the repo's single result type.
`--adversarial [--regime R]` runs the ISSUE-7 adversarial-stream policy
matrix (3 regimes × {adaptive policy, 3 fixed modes} on the device engine)
and writes `BENCH_adversarial.json` — the CI tests-adversarial matrix job
fans one job per regime and gates the per-regime decision counts exactly
via `benchmarks.check_regression --suite adversarial-<regime>`.
`--devices N` forces N host devices (XLA flag set **before** jax imports,
which is why all heavy imports live inside the entry points) and, with
`--smoke`, runs the sharded-engine + sharded-offload-hybrid cells instead,
writing `BENCH_sharded.json` — uploaded as an artifact by the CI
multi-device job and gated there via
`benchmarks.check_regression --suite sharded` (deterministic per-shard
transfer-row volume).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _module_registry():
    from benchmarks import (
        fig10_breakdown,
        fig12_sensitivity,
        fig2_edge_volume,
        fig7_response_time,
        fig8_access_volume,
        roofline,
        table4_accuracy,
        table5_degree,
        table6_memory,
    )

    return {
        "fig2": fig2_edge_volume,
        "table4": table4_accuracy,
        "fig7": fig7_response_time,
        "fig8": fig8_access_volume,
        "fig10": fig10_breakdown,
        "table5": table5_degree,
        "table6": table6_memory,
        "fig12": fig12_sensitivity,
        "roofline": roofline,
    }


def smoke() -> None:
    from benchmarks import fig7_response_time
    from benchmarks.common import ROWS

    t0 = time.time()
    fig7_response_time.smoke()
    wall = time.time() - t0
    out = {"rows": list(ROWS), "wall_s": round(wall, 2)}
    with open("BENCH_smoke.json", "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote BENCH_smoke.json ({wall:.1f}s)")


def adversarial(regime: str = "") -> None:
    from benchmarks import adversarial as cell
    from benchmarks.common import ROWS

    t0 = time.time()
    # always write the artifact, even when a policy gate expectation
    # fails the step — the emitted decision counts and cost ratios ARE
    # the diagnostics, and CI uploads the file `if: always()`
    try:
        cell.run([regime] if regime else None)
    finally:
        wall = time.time() - t0
        out = {"rows": list(ROWS), "wall_s": round(wall, 2)}
        with open("BENCH_adversarial.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote BENCH_adversarial.json ({wall:.1f}s)")


def smoke_sharded(num_shards: int) -> None:
    from benchmarks import fig7_response_time
    from benchmarks.common import ROWS

    t0 = time.time()
    # always write the artifact, even when the correctness/halo gate fails
    # the step — the telemetry rows (max|diff|, halo counts) ARE the
    # diagnostics for that failure, and CI uploads the file `if: always()`
    try:
        fig7_response_time.smoke_sharded(num_shards)
    finally:
        wall = time.time() - t0
        out = {"rows": list(ROWS), "wall_s": round(wall, 2),
               "devices": num_shards}
        with open("BENCH_sharded.json", "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote BENCH_sharded.json ({wall:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", type=str, default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fig7 cells, <60s; writes BENCH_smoke.json")
    ap.add_argument("--adversarial", action="store_true",
                    help="adversarial-stream policy matrix (ISSUE 7); "
                         "writes BENCH_adversarial.json")
    ap.add_argument("--regime", type=str, default="",
                    help="with --adversarial: run a single regime "
                         "(hub_burst/delete_heavy/feature_churn) — the CI "
                         "matrix fans one job per regime")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (pre-jax-init); with --smoke, "
                         "run the sharded cell and write BENCH_sharded.json")
    ap.add_argument("--out", type=str, default="",
                    help="write the emitted rows as a {rows, wall_s} JSON "
                         "artifact (the nightly CI job uploads "
                         "BENCH_nightly.json this way)")
    args = ap.parse_args()
    if args.devices:
        # must land in the env before anything imports jax
        assert "jax" not in sys.modules, "--devices must be set before jax imports"
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.devices}".strip()
        )
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    if args.smoke:
        if args.devices:
            smoke_sharded(args.devices)
        else:
            smoke()
        return
    if args.adversarial:
        adversarial(args.regime)
        return

    from benchmarks.common import ROWS, emit

    modules = _module_registry()
    names = [s for s in args.only.split(",") if s] or list(modules)
    t_run = time.time()
    failed = []
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            modules[name].run(quick=not args.full)
            emit(f"{name}/_module_wall_s", (time.time() - t0) * 1e6, "ok")
        except Exception as e:  # noqa
            traceback.print_exc()
            emit(f"{name}/_module_wall_s", (time.time() - t0) * 1e6, f"FAILED:{e}")
            failed.append(name)
    if args.out:
        # write even when a module failed: the partial rows are the
        # diagnostics, and CI uploads the artifact `if: always()`
        with open(args.out, "w") as f:
            json.dump({"rows": list(ROWS),
                       "wall_s": round(time.time() - t_run, 2)}, f, indent=2)
        print(f"wrote {args.out} ({time.time() - t_run:.1f}s)")
    if failed:
        print(f"FAILED modules: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
