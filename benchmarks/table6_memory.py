"""Paper Table VI: state memory — Full (features only) vs Inc-Naive
(h + a + nct) vs Inc with the recomputation-based storage optimization
(a + nct only, h rebuilt on demand)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit, gnn_params, setup
from repro.core import RTECEngine, make_model


def run(quick: bool = True):
    n = 5000 if quick else 50000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=1, batch_edges=5)
    model = make_model("gcn")
    params = gnn_params(model, [16, 16, 16])
    feats_only = x.nbytes

    naive = RTECEngine(model, params, wl.base, jnp.asarray(x), store_h=True)
    opt = RTECEngine(model, params, wl.base, jnp.asarray(x), store_h=False)
    nb, ob = naive.state_bytes(), opt.state_bytes()
    emit("table6/full_features_only_mb", 0, f"{feats_only/1e6:.2f}MB")
    emit("table6/inc_naive_mb", 0, f"{nb/1e6:.2f}MB={nb/feats_only:.2f}x_feat")
    emit("table6/inc_recompute_mb", 0, f"{ob/1e6:.2f}MB={ob/feats_only:.2f}x_feat")
    emit("table6/recompute_saving", 0, f"{1-ob/nb:.1%}")
