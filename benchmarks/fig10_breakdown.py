"""Paper Fig. 10: large-graph (out-of-memory / offloaded) runtime and its
breakdown: Upd+ASD (graph update + affected-subgraph detection), CGC
(computation-graph construction = planning), Comp (device compute)."""
from __future__ import annotations


from benchmarks.common import emit, gnn_params, make_engine, run_stream, setup
from repro.core import make_model
from repro.serve.offload import OffloadedRTECEngine


def run(quick: bool = True):
    n = 10000 if quick else 60000
    g, x, wl = setup("powerlaw", n=n, avg_degree=10.0, num_batches=3, batch_edges=20)
    for mname in ("gcn", "gat"):
        model = make_model(mname)
        params = gnn_params(model, [16, 16, 16])

        eng = OffloadedRTECEngine(model, params, wl.base, x)
        t, agg = run_stream(eng, wl)
        total = agg["graph_s"] + agg["plan_s"] + agg["exec_s"]
        emit(f"fig10/{mname}/offloaded_inc", t * 1e6,
             f"UpdASD={agg['graph_s']/total:.0%}|CGC={agg['plan_s']/total:.0%}|Comp={agg['exec_s']/total:.0%}")
        emit(f"fig10/{mname}/offload_rows_up", 0, str(eng.transfers.rows_up))

        full = make_engine("full", model, params, wl.base, x)
        tf, _ = run_stream(full, wl)
        emit(f"fig10/{mname}/full", tf * 1e6, f"inc_speedup={tf/t:.1f}x")
