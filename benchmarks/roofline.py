"""§Roofline report: reads experiments/dryrun/<mode>/*.json (produced by
`repro.launch.dryrun`) and emits the per-(arch × shape × mesh) roofline
table plus dominant-term and useful-fraction summaries."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mode: str = "opt"):
    out = []
    d = DRYRUN_DIR / mode
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def run(quick: bool = True, mode: str = "opt"):
    cells = load_cells(mode)
    if not cells:
        emit(f"roofline/{mode}/missing", 0, "run repro.launch.dryrun first")
        return
    for c in cells:
        tag = f"{c['arch']}/{c['shape']}/{c['mesh']}"
        if "skipped" in c:
            emit(f"roofline/{tag}", 0, "skipped:" + c["skipped"][:40])
            continue
        r = c["roofline"]
        uf = c.get("model_flops", {}).get("useful_fraction")
        ufs = f"{uf:.2f}" if uf is not None else "na"
        emit(
            f"roofline/{tag}",
            r["bound_s"] * 1e6,
            f"dom={r['dominant']}|c={r['compute_s']:.2e}|m={r['memory_s']:.2e}"
            f"|n={r['collective_s']:.2e}|useful={ufs}"
            f"|mem_gb={c['memory_analysis']['peak_est_gb']}",
        )
