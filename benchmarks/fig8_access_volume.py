"""Paper Figs. 8 & 11: vertex/edge access volumes per method, including the
constrained-model overhead NrtInc(c) (GAT/AGNN recompute in-edges of
destination-affected vertices)."""
from __future__ import annotations

from benchmarks.common import emit, gnn_params, make_engine, run_stream, setup
from repro.core import make_model


def run(quick: bool = True):
    n = 3000 if quick else 20000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=3, batch_edges=12)

    # unconstrained (sage) vs constrained (gat) — NrtInc(c)
    for mname in ("sage", "gat"):
        model = make_model(mname)
        params = gnn_params(model, [16, 16, 16])
        for method in ("full", "ns10", "uer", "inc"):
            eng = make_engine(method, model, params, wl.base, x)
            _, agg = run_stream(eng, wl)
            edges = agg["inc_edges"] + agg["full_edges"]
            tag = "inc(c)" if (method == "inc" and model.dest_dependent) else method
            emit(f"fig8/{mname}/{tag}_edges", 0, str(edges))
            emit(f"fig8/{mname}/{tag}_vertices", 0, str(agg["vertices"]))

    # constrained overhead: gat-inc vs sage-inc edge accesses
    model_s = make_model("sage")
    model_g = make_model("gat")
    ps = gnn_params(model_s, [16, 16, 16])
    pg = gnn_params(model_g, [16, 16, 16])
    es = run_stream(make_engine("inc", model_s, ps, wl.base, x), wl)[1]
    eg = run_stream(make_engine("inc", model_g, pg, wl.base, x), wl)[1]
    tot_s = es["inc_edges"] + es["full_edges"]
    tot_g = eg["inc_edges"] + eg["full_edges"]
    emit("fig8/constrained_edge_overhead", 0, f"{tot_g / max(tot_s, 1):.2f}x")
