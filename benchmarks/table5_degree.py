"""Paper Table V: edge-access reduction bucketed by destination degree
percentile (top-20%, mid-30%, bottom-50%) — power-law graphs concentrate
the savings on hub vertices."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, gnn_params, setup
from repro.core import make_model
from repro.core.affected import build_plan
from repro.core.baselines import forward_affected_sets


def run(quick: bool = True):
    n = 4000 if quick else 20000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=3, batch_edges=15)
    model = make_model("sage")
    params = gnn_params(model, [16, 16, 16])

    deg = wl.base.in_degree()
    order = np.argsort(-deg)
    top = set(order[: n // 5].tolist())
    mid = set(order[n // 5 : n // 2].tolist())

    # per-destination edge accesses: full (recompute in-edges of the L-hop
    # backward graph) vs inc (only affected edges)
    red_top = red_mid = red_bot = 0
    g_cur = wl.base
    for b in wl.batches:
        g_new = g_cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                    b.ins_weights, b.ins_etypes)
        fwd = forward_affected_sets(model, g_cur, g_new, b, 2)
        # full accesses per destination
        full_cnt = np.zeros(n, np.int64)
        need = set(fwd[-1].tolist())
        for l in range(1, -1, -1):
            for v in need:
                full_cnt[v] += g_new.in_degree()[v]
            nxt = set(need)
            for v in need:
                nxt |= set(g_new.in_neighbors(int(v)).tolist())
            need = nxt
        plan = build_plan(model, g_cur, g_new, b, 2)
        inc_cnt = np.zeros(n, np.int64)
        for lp in plan.layers:
            np.add.at(inc_cnt, lp.e_dst[lp.e_mask], 1)
            np.add.at(inc_cnt, lp.f_rows[lp.f_mask],
                      np.diff(g_new.in_indptr)[lp.f_rows[lp.f_mask]])
        saved = np.maximum(full_cnt - inc_cnt, 0)
        for v in np.nonzero(saved)[0]:
            if v in top:
                red_top += saved[v]
            elif v in mid:
                red_mid += saved[v]
            else:
                red_bot += saved[v]
        g_cur = g_new
    total = max(red_top + red_mid + red_bot, 1)
    emit("table5/top20_reduction_share", 0, f"{red_top/total:.1%}")
    emit("table5/mid30_reduction_share", 0, f"{red_mid/total:.1%}")
    emit("table5/bot50_reduction_share", 0, f"{red_bot/total:.1%}")
