"""Paper Fig. 7: per-batch response time + throughput (edge updates/s)
across six GNN models × methods, in-memory processing."""
from __future__ import annotations

from benchmarks.common import (
    emit,
    gnn_params,
    make_engine,
    run_stream,
    run_stream_pipelined,
    setup,
)
from repro.core import make_model

MODELS = ["gcn", "sage", "gin", "monet", "agnn", "gat"]
METHODS = ["full", "ns10", "ns5", "uer", "inc"]


def smoke():
    """One tiny cell (gcn × {full, inc}) for the CI benchmark-smoke job —
    finishes in well under a minute on one CPU (EXPERIMENTS.md §Perf).
    The ``inc_speedup_vs_full`` row is the blocking perf-gate metric
    (benchmarks/check_regression.py)."""
    # 6 batches → the steady-state min is over 5 post-warmup samples, which
    # keeps the gated ratio stable against one-off scheduler/GC spikes
    _, x, wl = setup("powerlaw", n=300, avg_degree=4.0, num_batches=6, batch_edges=8)
    model = make_model("gcn")
    params = gnn_params(model, [16, 16])
    times = {}
    for method in ("full", "inc"):
        eng = make_engine(method, model, params, wl.base, x)
        t, _ = run_stream(eng, wl)
        times[method] = t
        emit(f"fig7/smoke/gcn/{method}", t * 1e6, "")
    emit("fig7/smoke/gcn/inc_speedup_vs_full", times["inc"] * 1e6,
         f"{times['full'] / times['inc']:.2f}x")
    # plan/execute overlap (non-gating: includes any mid-stream retraces)
    eng = make_engine("inc", model, params, wl.base, x)
    t_pipe = run_stream_pipelined(eng, wl)
    emit("fig7/smoke/gcn/inc_pipelined", t_pipe * 1e6,
         f"{times['full'] / t_pipe:.2f}x")


def run(quick: bool = True):
    n = 2000 if quick else 8000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=4, batch_edges=16)
    upd_per_batch = wl.batches[0].num_updates
    for mname in MODELS:
        model = make_model(mname)
        params = gnn_params(model, [16, 16, 16])
        times = {}
        for method in METHODS:
            eng = make_engine(method, model, params, wl.base, x)
            t, agg = run_stream(eng, wl)
            times[method] = t
            thpt = upd_per_batch / t
            emit(f"fig7/{mname}/{method}", t * 1e6, f"{thpt:.0f}_upd_per_s")
        for method in ("full", "uer", "ns10"):
            emit(
                f"fig7/{mname}/inc_speedup_vs_{method}", times["inc"] * 1e6,
                f"{times[method] / times['inc']:.2f}x",
            )
