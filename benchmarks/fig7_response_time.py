"""Paper Fig. 7: per-batch response time + throughput (edge updates/s)
across six GNN models × methods, in-memory processing.

Also hosts the serving-frontend cells (ISSUE 6): the smoke job's
deterministic read-counter cell (`smoke_frontend`, CI-gated exactly) and
the full sweep's latency-vs-throughput curve (`run_serving`, telemetry)."""
from __future__ import annotations

from benchmarks.common import (
    emit,
    emit_stream_stats,
    gnn_params,
    make_engine,
    run_stream,
    run_stream_pipelined,
    setup,
)
from repro.core import make_model

MODELS = ["gcn", "sage", "gin", "monet", "agnn", "gat"]
METHODS = ["full", "ns10", "ns5", "uer", "inc"]


def smoke():
    """Tiny cells for the CI benchmark-smoke job — well under a minute on
    one CPU (EXPERIMENTS.md §Perf).  Emits the blocking perf-gate metric
    matrix (benchmarks/check_regression.py): gcn speedup (unconstrained
    path), gat speedup (§IV-C constrained path), and the offload engine's
    deterministic transfer-row volume."""
    # 6 batches → the steady-state min is over 5 post-warmup samples, which
    # keeps the gated ratios stable against one-off scheduler/GC spikes
    _, x, wl = setup("powerlaw", n=300, avg_degree=4.0, num_batches=6, batch_edges=8)
    for mname in ("gcn", "gat"):
        model = make_model(mname)
        params = gnn_params(model, [16, 16])
        times = {}
        for method in ("full", "inc"):
            eng = make_engine(method, model, params, wl.base, x)
            t, _ = run_stream(eng, wl)
            times[method] = t
            emit(f"fig7/smoke/{mname}/{method}", t * 1e6, "")
        emit(f"fig7/smoke/{mname}/inc_speedup_vs_full", times["inc"] * 1e6,
             f"{times['full'] / times['inc']:.2f}x")
        if mname == "gcn":
            # plan/execute overlap (non-gating).  apply_stream reports one
            # wall time for the whole overlapped run, so unlike run_stream's
            # per-batch min a single scheduler/GC spike or mid-stream retrace
            # is charged to the entire measurement: take the best of a few
            # fresh-engine repeats instead.
            t_pipe = min(
                run_stream_pipelined(
                    make_engine("inc", model, params, wl.base, x), wl)
                for _ in range(3)
            )
            emit("fig7/smoke/gcn/inc_pipelined", t_pipe * 1e6,
                 f"{times['full'] / t_pipe:.2f}x")
    # offload transfer volume: deterministic row counts, tight gate bound.
    # Runs through the unified apply_stream (ISSUE 4: the offload engine
    # returns the same StreamStats as every other engine) — staging and
    # write-back volume is identical to the per-batch path.
    from repro.serve.offload import OffloadedRTECEngine

    model = make_model("gcn")
    params = gnn_params(model, [16, 16])
    # min over 3 fresh-engine repeats, same rationale as inc_pipelined
    # above: a single apply_stream wall charges every per-shape-bucket jit
    # compile of incremental_layer (~2.4s, >95% of the old 2403ms cell) to
    # a 6-batch stream; the repeats share the in-process jit cache, so the
    # min measures the steady-state stream the serving path actually runs
    off = ss = None
    for _ in range(3):
        eng = OffloadedRTECEngine(model, params, wl.base, x)
        s = eng.apply_stream(wl.batches)
        if ss is None or s.wall_s < ss.wall_s:
            off, ss = eng, s  # keep wall and plan_s from the same run;
            # the gated counters are deterministic across repeats
    # overlap metric set (ISSUE 5) — deterministic counters, CI-gated:
    # prefetch_hits is structural (every batch after the first plans while
    # the previous executes), staged_bytes is a plan-determined payload
    # volume; sync_wait vs compute is telemetry only (timing noise).
    # Rows render through StreamStats.as_dict (the single result type).
    emit_stream_stats("fig7/smoke/gcn/offload", ss,
                      expect_prefetch=len(wl.batches) - 1)
    emit("fig7/smoke/gcn/offload_transfer_rows",
         float(off.transfers.total_rows), f"{off.transfers.total_rows}rows")
    smoke_frontend(model, params, wl, x)
    smoke_cache()
    smoke_fusion()


def smoke_fusion():
    """Batch-window fusion cell (ISSUE 9): a high-rate small-batch stream
    of region-disjoint updates on a ring lattice — the workload fusion is
    built for (each batch's plan is tiny and independent, so dispatch
    overhead dominates).  Runs the offload engine fused (window=4) vs
    serial, fails the step outright on any embedding divergence (fusion
    must be bitwise invisible), and emits the exact fusion counters
    (expectations shared with the gate via
    ``check_regression.FUSION_EXPECTED``): 12 fusable batches under a
    4-deep lookahead fuse into exactly 3 windows, so the stream executes
    in 3 device dispatches instead of 12 — the dispatch count drops by
    exactly ``fused_batches - fusion_windows``."""
    import numpy as np

    from benchmarks.check_regression import FUSION_EXPECTED
    from repro.core import make_model
    from repro.graph.csr import CSRGraph
    from repro.graph.generators import random_features
    from repro.graph.streaming import UpdateBatch
    from repro.serve import EngineConfig, FusionConfig, create_engine

    n, num, d = 600, 12, 8
    # ring lattice (in-edges from i+1, i+2): updates confined to regions
    # 45 rows apart have provably disjoint L=2 footprints, so every
    # window's independence check passes — the counters are structural
    idx = np.arange(n, dtype=np.int64)
    src = np.concatenate([(idx + 1) % n, (idx + 2) % n])
    dst = np.concatenate([idx, idx])
    g = CSRGraph.from_edges(n, src, dst)
    rng = np.random.default_rng(0)
    batches = []
    for i in range(num):
        base = (i * 45) % n
        batches.append(UpdateBatch(
            ins_src=np.array([(base + 1) % n], np.int64),
            ins_dst=np.array([(base + 5) % n], np.int64),
            del_src=np.array([], np.int64),
            del_dst=np.array([], np.int64),
            feat_vertices=np.array([(base + 7) % n], np.int64),
            feat_values=rng.standard_normal((1, d)).astype(np.float32)))
    x, _ = random_features(n, d, seed=0)
    model = make_model("gcn")
    params = gnn_params(model, [d, d])
    runs = {}
    for fused in (False, True):
        eng = create_engine("offload", EngineConfig(
            model=model, graph=g, x=x, params=params,
            fusion=FusionConfig(window=4) if fused else None))
        ss = eng.apply_stream(batches)
        runs[fused] = (np.asarray(eng.embeddings), ss.as_dict())
    emb_s, d_s = runs[False]
    emb_f, d_f = runs[True]
    exp = FUSION_EXPECTED
    # dispatch count: every batch outside a window is one dispatch, every
    # window is one dispatch — the identity the test suite pins per-cell
    dispatches = num - (d_f["fused_batches"] - d_f["fusion_windows"])
    emit("fig7/smoke/gcn/fusion_windows", float(d_f["fusion_windows"]),
         f"expect_{exp['windows']}")
    emit("fig7/smoke/gcn/fusion_fused_batches", float(d_f["fused_batches"]),
         f"expect_{exp['fused_batches']}")
    emit("fig7/smoke/gcn/fusion_dispatches", float(dispatches),
         f"expect_{exp['dispatches']}")
    failures = []
    if d_f["fusion_fallbacks"] != 0:
        failures.append(
            f"fusion_fallbacks={d_f['fusion_fallbacks']} on an all-fusable "
            "stream (expected 0)")
    if d_s["fusion_windows"] != 0 or d_s["fused_batches"] != 0:
        failures.append("serial run reported nonzero fusion counters")
    if not np.array_equal(emb_s, emb_f):
        diff = float(np.abs(emb_s - emb_f).max())
        failures.append(
            f"fused-vs-serial max|diff|={diff:g} (expected bitwise 0)")
    if failures:
        raise SystemExit("fusion smoke gate FAILED: " + "; ".join(failures))


def smoke_cache():
    """Device hot-row cache cell (ISSUE 8): the offload engine over the
    deterministic hub_burst stream, cached vs uncached.  Emits the gated
    ratio row (uncached/cached staged bytes — the acceptance's ≥30%
    reduction is a 1.43x floor) and the exact hit/miss/eviction counters
    (expectations shared with the gate via
    ``check_regression.CACHE_EXPECTED``), and fails the step outright on
    any cached-vs-uncached embedding divergence — the cache must be
    bitwise invisible to the math."""
    import numpy as np

    from benchmarks.check_regression import CACHE_EXPECTED
    from repro.core import make_model
    from repro.graph import make_adversarial_stream
    from repro.graph.generators import random_features
    from repro.serve import CacheConfig, EngineConfig, create_engine

    wl = make_adversarial_stream("hub_burst", num_batches=6)
    x, _ = random_features(wl.base.n, 8, seed=0)
    model = make_model("gcn")
    params = gnn_params(model, [8, 8])
    runs = {}
    for cached in (False, True):
        eng = create_engine("offload", EngineConfig(
            model=model, graph=wl.base, x=x, params=params,
            cache=CacheConfig(capacity_rows=256) if cached else None))
        ss = eng.apply_stream(wl.batches)
        runs[cached] = (np.asarray(eng.embeddings), ss.as_dict())
    emb_u, d_u = runs[False]
    emb_c, d_c = runs[True]
    exp = CACHE_EXPECTED["smoke"]
    ratio = d_u["staged_bytes"] / max(d_c["staged_bytes"], 1)
    emit("fig7/smoke/gcn/cache_staged_bytes", float(d_c["staged_bytes"]),
         f"{ratio:.2f}x")
    emit("fig7/smoke/gcn/cache_hit_rows", float(d_c["cache_hit_rows"]),
         f"expect_{exp['hit_rows']}")
    emit("fig7/smoke/gcn/cache_miss_rows", float(d_c["cache_miss_rows"]),
         f"expect_{exp['miss_rows']}")
    emit("fig7/smoke/gcn/cache_evictions", float(d_c["cache_evictions"]),
         f"expect_{exp['evictions']}")
    if not np.array_equal(emb_u, emb_c):
        diff = float(np.abs(emb_u - emb_c).max())
        raise SystemExit(
            f"cache smoke gate FAILED: cached-vs-uncached max|diff|={diff:g} "
            "(expected bitwise 0)")


def smoke_frontend(model, params, wl, x):
    """Serving front-end smoke cell (ISSUE 6): reads interleaved with the
    existing 6-batch stream on the offload engine, deterministic schedule —
    before batch i one read pinned at the current version i plus, once
    version ≥ 2, one pinned at i-2.  Over 6 batches that is 10 served reads
    with cumulative staleness 8 (4 × 2 batches), both CI-gated exactly;
    read_p99 is latency telemetry (never gated)."""
    import numpy as np

    from repro.serve import ServingFrontend, create_engine, EngineConfig

    eng = create_engine("offload", EngineConfig(
        model=model, graph=wl.base, x=x, params=params))
    fr = ServingFrontend(eng, max_pending_reads=16, max_versions=4)
    rows = np.arange(0, wl.base.n, 17)
    for b in wl.batches:
        fr.submit_read(rows)  # pinned at the current version
        if fr.version >= 2:
            fr.submit_read(rows, version=fr.version - 2)
        fr.apply_batch(b)
    fr.drain()
    n_fresh = len(wl.batches)
    n_stale = len(wl.batches) - 2
    emit_stream_stats("fig7/smoke/gcn/frontend", fr.stats(),
                      expect_reads=n_fresh + n_stale,
                      expect_staleness=2 * n_stale)


def smoke_sharded(num_shards: int):
    """Sharded-engine smoke cell (the CI multi-device job's artifact):
    single-device pipelined engine vs :class:`ShardedRTECEngine` on the same
    stream, plus the per-batch frontier (halo) row count the psum exchange
    is bounded to, and the sharded-vs-single max |Δ| as an equivalence
    telemetry row."""
    import numpy as np

    from repro.core import ShardedRTECEngine

    _, x, wl = setup("powerlaw", n=300, avg_degree=4.0, num_batches=6, batch_edges=8)
    model = make_model("gcn")
    params = gnn_params(model, [16, 16])
    single = make_engine("inc", model, params, wl.base, x)
    t_single, _ = run_stream(single, wl)
    emit("fig7/sharded/gcn/single", t_single * 1e6, "")
    sharded = ShardedRTECEngine(model, params, wl.base, x, num_shards=num_shards)
    t_sharded, _ = run_stream(sharded, wl)
    emit(f"fig7/sharded/gcn/sharded{num_shards}", t_sharded * 1e6,
         f"{t_single / t_sharded:.2f}x")
    halo_per_batch = sharded.halo_rows_total / len(wl.batches)
    emit("fig7/sharded/gcn/halo_rows_per_batch", halo_per_batch,
         f"S={num_shards}")
    diff = float(np.abs(np.asarray(single.embeddings) - sharded.embeddings).max())
    emit("fig7/sharded/gcn/max_abs_diff_vs_single", diff, "")
    # ---- sharded-offload hybrid cell (ISSUE 4) ----
    from repro.serve.offload import ShardedOffloadRTECEngine

    hybrid = ShardedOffloadRTECEngine(model, params, wl.base, x,
                                      num_shards=num_shards)
    t_hybrid, _ = run_stream(hybrid, wl)
    emit(f"fig7/sharded/gcn/hybrid{num_shards}", t_hybrid * 1e6,
         f"{t_single / t_hybrid:.2f}x")
    diff_h = float(np.abs(np.asarray(single.embeddings) - hybrid.embeddings).max())
    emit("fig7/sharded/gcn/hybrid_max_abs_diff_vs_single", diff_h, "")
    # per-shard H2D+D2H row volume: deterministic (no timing noise), gated
    # by check_regression's sharded suite — growth means the per-shard
    # compact staging or remap tables regressed toward O(V) transfers
    rows_per_shard = int(hybrid.per_shard_rows.max())
    emit("fig7/sharded/gcn/hybrid_transfer_rows_per_shard",
         float(rows_per_shard), f"S={num_shards}")
    emit("fig7/sharded/gcn/hybrid_peak_device_bytes",
         float(hybrid.peak_device_bytes),
         f"state_{hybrid.state_bytes()}B")
    # hybrid overlap cell (ISSUE 5): a fresh engine runs the overlapped
    # stream path so the staging pipeline's deterministic counters can be
    # gated (check_regression --suite sharded) without disturbing the
    # per-batch transfer accounting gated above
    hybrid_pipe = ShardedOffloadRTECEngine(model, params, wl.base, x,
                                           num_shards=num_shards)
    ssh = hybrid_pipe.apply_stream(wl.batches)
    emit_stream_stats("fig7/sharded/gcn/hybrid", ssh,
                      expect_prefetch=len(wl.batches) - 1)
    diff_p = float(np.abs(np.asarray(single.embeddings)
                          - hybrid_pipe.embeddings).max())
    emit("fig7/sharded/gcn/hybrid_stream_max_abs_diff_vs_single", diff_p, "")
    # the cell gates correctness + halo/transfer volume, not wall time (on
    # CPU CI the forced "devices" oversubscribe the cores): fail the CI step
    # outright on divergence (the gcn path is exact for both engines) or on
    # halo traffic past the frontier-only bound (~12 rows/batch measured; 64
    # leaves headroom for workload drift while still catching a
    # broadcast-everything regression against the 300-vertex graph)
    failures = []
    if diff != 0.0:
        failures.append(f"sharded-vs-single max|diff|={diff:g} (expected 0)")
    if diff_h != 0.0:
        failures.append(f"hybrid-vs-single max|diff|={diff_h:g} (expected 0)")
    if diff_p != 0.0:
        failures.append(
            f"hybrid-stream-vs-single max|diff|={diff_p:g} (expected 0)")
    if halo_per_batch > 64:
        failures.append(f"halo_rows_per_batch={halo_per_batch:.1f} exceeds 64")
    failures += _sharded_cache_cell(num_shards)
    failures += _sharded_comms_cell(num_shards, model, params, wl, x)
    if failures:
        raise SystemExit("sharded smoke gate FAILED: " + "; ".join(failures))


def _sharded_comms_cell(num_shards, model, params, wl, x):
    """Per-consumer halo exchange (ISSUE 10): the ppermute send-recv
    schedules vs the legacy global-frontier psum on the same deterministic
    stream.  Emits the gated ``comms_halo_rows_sent`` (exact: unique
    (owner, consumer, row) deliveries are a pure function of the plans)
    and the psum broadcast volume as its pinned ceiling; fails the CI step
    outright on any embedding divergence (the two modes are bitwise-equal
    by construction) or if the per-consumer volume is not strictly below
    the broadcast ceiling.  Returns failure strings for the caller's
    SystemExit."""
    import numpy as np

    from benchmarks.check_regression import COMMS_EXPECTED
    from repro.dist.sharding import CommsConfig
    from repro.serve import EngineConfig, create_engine

    runs = {}
    for mode in ("psum", "ppermute"):
        eng = create_engine("sharded", EngineConfig(
            model=model, graph=wl.base, x=x, params=params,
            num_shards=num_shards, comms=CommsConfig(halo=mode)))
        ss = eng.apply_stream(wl.batches)
        runs[mode] = (np.asarray(eng.embeddings), ss)
    emb_p, ss_p = runs["psum"]
    emb_q, ss_q = runs["ppermute"]
    exp = COMMS_EXPECTED["sharded"]
    emit("fig7/sharded/gcn/comms_halo_rows_sent",
         float(ss_q.comms_halo_rows_sent),
         f"expect_{exp['halo_rows_sent']}")
    emit("fig7/sharded/gcn/comms_halo_bytes",
         float(ss_q.comms_halo_bytes), f"S={num_shards}")
    emit("fig7/sharded/gcn/comms_psum_ceiling_rows",
         float(ss_p.comms_halo_rows_sent),
         f"expect_{exp['psum_ceiling_rows']}")
    failures = []
    if not np.array_equal(emb_p, emb_q):
        diff = float(np.abs(emb_p - emb_q).max())
        failures.append(
            f"ppermute-vs-psum max|diff|={diff:g} (expected 0)")
    if not 0 < ss_q.comms_halo_rows_sent < ss_p.comms_halo_rows_sent:
        failures.append(
            f"comms_halo_rows_sent={ss_q.comms_halo_rows_sent} not "
            f"strictly below the psum broadcast ceiling "
            f"{ss_p.comms_halo_rows_sent}")
    return failures


def _sharded_cache_cell(num_shards: int):
    """Hot-row cache on the sharded offload hybrid (ISSUE 8): hub_burst
    cached vs uncached, same contract as ``smoke_cache`` — ratio-gated
    staged bytes plus exact residency counters.  Returns failure strings
    (the caller folds them into the sharded gate's SystemExit).  The
    pinned ``CACHE_EXPECTED['sharded']`` counts assume the CI job's 8-way
    mesh: per-shard halo rows make residency S-dependent."""
    import numpy as np

    from benchmarks.check_regression import CACHE_EXPECTED
    from repro.graph import make_adversarial_stream
    from repro.graph.generators import random_features
    from repro.serve import CacheConfig, EngineConfig, create_engine

    wl = make_adversarial_stream("hub_burst", num_batches=6)
    x, _ = random_features(wl.base.n, 8, seed=0)
    model = make_model("gcn")
    params = gnn_params(model, [8, 8])
    runs = {}
    for cached in (False, True):
        eng = create_engine("sharded_offload", EngineConfig(
            model=model, graph=wl.base, x=x, params=params,
            num_shards=num_shards,
            cache=CacheConfig(capacity_rows=256) if cached else None))
        ss = eng.apply_stream(wl.batches)
        runs[cached] = (np.asarray(eng.embeddings), ss.as_dict())
    emb_u, d_u = runs[False]
    emb_c, d_c = runs[True]
    exp = CACHE_EXPECTED["sharded"]
    ratio = d_u["staged_bytes"] / max(d_c["staged_bytes"], 1)
    emit("fig7/sharded/gcn/hybrid_cache_staged_bytes",
         float(d_c["staged_bytes"]), f"{ratio:.2f}x")
    emit("fig7/sharded/gcn/hybrid_cache_hit_rows",
         float(d_c["cache_hit_rows"]), f"expect_{exp['hit_rows']}")
    emit("fig7/sharded/gcn/hybrid_cache_miss_rows",
         float(d_c["cache_miss_rows"]), f"expect_{exp['miss_rows']}")
    emit("fig7/sharded/gcn/hybrid_cache_evictions",
         float(d_c["cache_evictions"]), f"expect_{exp['evictions']}")
    if not np.array_equal(emb_u, emb_c):
        diff = float(np.abs(emb_u - emb_c).max())
        return [f"hybrid cached-vs-uncached max|diff|={diff:g} (expected 0)"]
    return []


def run(quick: bool = True):
    n = 2000 if quick else 8000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=4, batch_edges=16)
    upd_per_batch = wl.batches[0].num_updates
    for mname in MODELS:
        model = make_model(mname)
        params = gnn_params(model, [16, 16, 16])
        times = {}
        for method in METHODS:
            eng = make_engine(method, model, params, wl.base, x)
            t, agg = run_stream(eng, wl)
            times[method] = t
            thpt = upd_per_batch / t
            emit(f"fig7/{mname}/{method}", t * 1e6, f"{thpt:.0f}_upd_per_s")
        for method in ("full", "uer", "ns10"):
            emit(
                f"fig7/{mname}/inc_speedup_vs_{method}", times["inc"] * 1e6,
                f"{times[method] / times['inc']:.2f}x",
            )
    run_serving(x, wl)


def run_serving(x, wl):
    """Latency-vs-throughput serving cells (ISSUE 6, full sweep only): the
    gcn offload engine under increasing read pressure — r reads per update
    batch, each pinned one version back — reporting update throughput
    against read p50/p99.  Telemetry rows (timing on a shared CI host is
    noise); the deterministic read counters are gated in the *smoke* cell."""
    import numpy as np

    from repro.serve import EngineConfig, ServingFrontend, create_engine

    model = make_model("gcn")
    params = gnn_params(model, [16, 16, 16])
    upd_per_batch = wl.batches[0].num_updates
    rows = np.arange(0, wl.base.n, 7)
    # un-emitted warmup stream: charge the per-shape-bucket jit compiles
    # here, not to the first sweep point (the inc_pipelined precedent —
    # otherwise the r=0 cell eats ~10s of compile and the curve reads
    # backwards)
    warm = create_engine("offload", EngineConfig(
        model=model, graph=wl.base, x=x, params=params))
    warm.apply_stream(wl.batches)
    for r in (0, 1, 4, 16):
        eng = create_engine("offload", EngineConfig(
            model=model, graph=wl.base, x=x, params=params))
        fr = ServingFrontend(eng, max_pending_reads=4 * max(r, 1) + 1)
        for b in wl.batches:
            for _ in range(r):
                fr.submit_read(rows, version=max(0, fr.version - 1))
            fr.apply_batch(b)
        fr.drain()
        ss = fr.stats()
        thpt = upd_per_batch * len(wl.batches) / max(ss.wall_s, 1e-9)
        emit(f"fig7/serving/gcn/reads{r}_read_p99", ss.read_p99_s * 1e6,
             f"p50_{ss.read_p50_s * 1e6:.0f}us")
        emit(f"fig7/serving/gcn/reads{r}_throughput", ss.wall_s * 1e6,
             f"{thpt:.0f}_upd_per_s")
