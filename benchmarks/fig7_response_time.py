"""Paper Fig. 7: per-batch response time + throughput (edge updates/s)
across six GNN models × methods, in-memory processing."""
from __future__ import annotations

from benchmarks.common import (
    emit,
    gnn_params,
    make_engine,
    run_stream,
    run_stream_pipelined,
    setup,
)
from repro.core import make_model

MODELS = ["gcn", "sage", "gin", "monet", "agnn", "gat"]
METHODS = ["full", "ns10", "ns5", "uer", "inc"]


def smoke():
    """Tiny cells for the CI benchmark-smoke job — well under a minute on
    one CPU (EXPERIMENTS.md §Perf).  Emits the blocking perf-gate metric
    matrix (benchmarks/check_regression.py): gcn speedup (unconstrained
    path), gat speedup (§IV-C constrained path), and the offload engine's
    deterministic transfer-row volume."""
    # 6 batches → the steady-state min is over 5 post-warmup samples, which
    # keeps the gated ratios stable against one-off scheduler/GC spikes
    _, x, wl = setup("powerlaw", n=300, avg_degree=4.0, num_batches=6, batch_edges=8)
    for mname in ("gcn", "gat"):
        model = make_model(mname)
        params = gnn_params(model, [16, 16])
        times = {}
        for method in ("full", "inc"):
            eng = make_engine(method, model, params, wl.base, x)
            t, _ = run_stream(eng, wl)
            times[method] = t
            emit(f"fig7/smoke/{mname}/{method}", t * 1e6, "")
        emit(f"fig7/smoke/{mname}/inc_speedup_vs_full", times["inc"] * 1e6,
             f"{times['full'] / times['inc']:.2f}x")
        if mname == "gcn":
            # plan/execute overlap (non-gating).  apply_stream reports one
            # wall time for the whole overlapped run, so unlike run_stream's
            # per-batch min a single scheduler/GC spike or mid-stream retrace
            # is charged to the entire measurement: take the best of a few
            # fresh-engine repeats instead.
            t_pipe = min(
                run_stream_pipelined(
                    make_engine("inc", model, params, wl.base, x), wl)
                for _ in range(3)
            )
            emit("fig7/smoke/gcn/inc_pipelined", t_pipe * 1e6,
                 f"{times['full'] / t_pipe:.2f}x")
    # offload transfer volume: deterministic row counts, tight gate bound.
    # Runs through the unified apply_stream (ISSUE 4: the offload engine
    # returns the same StreamStats as every other engine) — staging and
    # write-back volume is identical to the per-batch path.
    from repro.serve.offload import OffloadedRTECEngine

    model = make_model("gcn")
    params = gnn_params(model, [16, 16])
    # min over 3 fresh-engine repeats, same rationale as inc_pipelined
    # above: a single apply_stream wall charges every per-shape-bucket jit
    # compile of incremental_layer (~2.4s, >95% of the old 2403ms cell) to
    # a 6-batch stream; the repeats share the in-process jit cache, so the
    # min measures the steady-state stream the serving path actually runs
    off = ss = None
    for _ in range(3):
        eng = OffloadedRTECEngine(model, params, wl.base, x)
        s = eng.apply_stream(wl.batches)
        if ss is None or s.wall_s < ss.wall_s:
            off, ss = eng, s  # keep wall and plan_s from the same run;
            # the gated counters are deterministic across repeats
    emit("fig7/smoke/gcn/offload_stream_wall", ss.wall_s * 1e6,
         f"plan_{ss.plan_s * 1e6:.0f}us")
    emit("fig7/smoke/gcn/offload_transfer_rows",
         float(off.transfers.total_rows), f"{off.transfers.total_rows}rows")
    # overlap metric set (ISSUE 5) — deterministic counters, CI-gated:
    # prefetch_hits is structural (every batch after the first plans while
    # the previous executes), staged_bytes is a plan-determined payload
    # volume; sync_wait vs compute is telemetry only (timing noise)
    emit("fig7/smoke/gcn/offload_prefetch_hits", float(ss.prefetch_hits),
         f"expect_{len(wl.batches) - 1}")
    emit("fig7/smoke/gcn/offload_staged_bytes", float(ss.staged_bytes),
         f"sync_wait_{ss.sync_wait_s * 1e6:.0f}us_compute_"
         f"{ss.compute_s * 1e6:.0f}us")


def smoke_sharded(num_shards: int):
    """Sharded-engine smoke cell (the CI multi-device job's artifact):
    single-device pipelined engine vs :class:`ShardedRTECEngine` on the same
    stream, plus the per-batch frontier (halo) row count the psum exchange
    is bounded to, and the sharded-vs-single max |Δ| as an equivalence
    telemetry row."""
    import numpy as np

    from repro.core import ShardedRTECEngine

    _, x, wl = setup("powerlaw", n=300, avg_degree=4.0, num_batches=6, batch_edges=8)
    model = make_model("gcn")
    params = gnn_params(model, [16, 16])
    single = make_engine("inc", model, params, wl.base, x)
    t_single, _ = run_stream(single, wl)
    emit("fig7/sharded/gcn/single", t_single * 1e6, "")
    sharded = ShardedRTECEngine(model, params, wl.base, x, num_shards=num_shards)
    t_sharded, _ = run_stream(sharded, wl)
    emit(f"fig7/sharded/gcn/sharded{num_shards}", t_sharded * 1e6,
         f"{t_single / t_sharded:.2f}x")
    halo_per_batch = sharded.halo_rows_total / len(wl.batches)
    emit("fig7/sharded/gcn/halo_rows_per_batch", halo_per_batch,
         f"S={num_shards}")
    diff = float(np.abs(np.asarray(single.embeddings) - sharded.embeddings).max())
    emit("fig7/sharded/gcn/max_abs_diff_vs_single", diff, "")
    # ---- sharded-offload hybrid cell (ISSUE 4) ----
    from repro.serve.offload import ShardedOffloadRTECEngine

    hybrid = ShardedOffloadRTECEngine(model, params, wl.base, x,
                                      num_shards=num_shards)
    t_hybrid, _ = run_stream(hybrid, wl)
    emit(f"fig7/sharded/gcn/hybrid{num_shards}", t_hybrid * 1e6,
         f"{t_single / t_hybrid:.2f}x")
    diff_h = float(np.abs(np.asarray(single.embeddings) - hybrid.embeddings).max())
    emit("fig7/sharded/gcn/hybrid_max_abs_diff_vs_single", diff_h, "")
    # per-shard H2D+D2H row volume: deterministic (no timing noise), gated
    # by check_regression's sharded suite — growth means the per-shard
    # compact staging or remap tables regressed toward O(V) transfers
    rows_per_shard = int(hybrid.per_shard_rows.max())
    emit("fig7/sharded/gcn/hybrid_transfer_rows_per_shard",
         float(rows_per_shard), f"S={num_shards}")
    emit("fig7/sharded/gcn/hybrid_peak_device_bytes",
         float(hybrid.peak_device_bytes),
         f"state_{hybrid.state_bytes()}B")
    # hybrid overlap cell (ISSUE 5): a fresh engine runs the overlapped
    # stream path so the staging pipeline's deterministic counters can be
    # gated (check_regression --suite sharded) without disturbing the
    # per-batch transfer accounting gated above
    hybrid_pipe = ShardedOffloadRTECEngine(model, params, wl.base, x,
                                           num_shards=num_shards)
    ssh = hybrid_pipe.apply_stream(wl.batches)
    emit("fig7/sharded/gcn/hybrid_stream_wall", ssh.wall_s * 1e6,
         f"plan_{ssh.plan_s * 1e6:.0f}us")
    emit("fig7/sharded/gcn/hybrid_prefetch_hits", float(ssh.prefetch_hits),
         f"expect_{len(wl.batches) - 1}")
    emit("fig7/sharded/gcn/hybrid_staged_bytes", float(ssh.staged_bytes),
         f"sync_wait_{ssh.sync_wait_s * 1e6:.0f}us_compute_"
         f"{ssh.compute_s * 1e6:.0f}us")
    diff_p = float(np.abs(np.asarray(single.embeddings)
                          - hybrid_pipe.embeddings).max())
    emit("fig7/sharded/gcn/hybrid_stream_max_abs_diff_vs_single", diff_p, "")
    # the cell gates correctness + halo/transfer volume, not wall time (on
    # CPU CI the forced "devices" oversubscribe the cores): fail the CI step
    # outright on divergence (the gcn path is exact for both engines) or on
    # halo traffic past the frontier-only bound (~12 rows/batch measured; 64
    # leaves headroom for workload drift while still catching a
    # broadcast-everything regression against the 300-vertex graph)
    failures = []
    if diff != 0.0:
        failures.append(f"sharded-vs-single max|diff|={diff:g} (expected 0)")
    if diff_h != 0.0:
        failures.append(f"hybrid-vs-single max|diff|={diff_h:g} (expected 0)")
    if diff_p != 0.0:
        failures.append(
            f"hybrid-stream-vs-single max|diff|={diff_p:g} (expected 0)")
    if halo_per_batch > 64:
        failures.append(f"halo_rows_per_batch={halo_per_batch:.1f} exceeds 64")
    if failures:
        raise SystemExit("sharded smoke gate FAILED: " + "; ".join(failures))


def run(quick: bool = True):
    n = 2000 if quick else 8000
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=4, batch_edges=16)
    upd_per_batch = wl.batches[0].num_updates
    for mname in MODELS:
        model = make_model(mname)
        params = gnn_params(model, [16, 16, 16])
        times = {}
        for method in METHODS:
            eng = make_engine(method, model, params, wl.base, x)
            t, agg = run_stream(eng, wl)
            times[method] = t
            thpt = upd_per_batch / t
            emit(f"fig7/{mname}/{method}", t * 1e6, f"{thpt:.0f}_upd_per_s")
        for method in ("full", "uer", "ns10"):
            emit(
                f"fig7/{mname}/inc_speedup_vs_{method}", times["inc"] * 1e6,
                f"{times[method] / times['inc']:.2f}x",
            )
