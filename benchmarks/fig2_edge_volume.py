"""Paper Fig. 2: processed edge volume per method, normalized to the
affected subgraph (AS).  AS = the incremental engine's processed edges (the
update-propagation paths — exactly the red region of Fig. 1)."""
from __future__ import annotations


from benchmarks.common import emit, gnn_params, make_engine, run_stream, setup
from repro.core import make_model

METHODS = ["full", "ns5", "ns10", "uer", "inc"]


def run(quick: bool = True):
    cases = [
        ("powerlaw", 3000, 8.0),
        ("dense", 800, 48.0),  # Reddit-like high average degree
        ("uniform", 3000, 6.0),
    ]
    for kind, n, deg in cases:
        g, x, wl = setup(kind, n=n, avg_degree=deg, num_batches=3, batch_edges=10)
        model = make_model("sage")
        params = gnn_params(model, [16, 16, 16])
        volumes = {}
        for m in METHODS:
            eng = make_engine(m, model, params, wl.base, x)
            t, agg = run_stream(eng, wl)
            volumes[m] = agg["inc_edges"] + agg["full_edges"]
            if m == "inc":
                t_inc = t
        as_edges = max(volumes["inc"], 1)
        for m in METHODS:
            emit(
                f"fig2/{kind}/{m}_edges_vs_AS",
                t_inc * 1e6,
                f"{volumes[m] / as_edges:.2f}x_AS",
            )
        redundant = 1.0 - as_edges / max(volumes["full"], 1)
        emit(f"fig2/{kind}/full_redundant_frac", 0.0, f"{redundant:.2%}")
