"""Paper Table IV: inference-accuracy comparison on a drifting graph.

Synthetic SBM-community node classification (structure-dependent labels):
features are noisy community indicators, edges mostly intra-community, so a
trained GraphSAGE needs *fresh neighborhoods* for accurate predictions.

Methods: MTEC-Optimal (retrain+recompute each batch), MTEC-Period (stale,
refresh every T), RTEC-NS{5,10,20}, RTEC(NrtInc).  The paper's headline:
NrtInc ≈ Optimal > NS ≥ Period.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import RTECEngine, RTECSample, full_forward, make_model
from repro.graph.csr import CSRGraph
from repro.graph.streaming import UpdateBatch


def make_sbm(n: int, k: int, p_intra: float, deg: float, seed: int):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, n)
    m = int(n * deg)
    src = rng.integers(0, n, 2 * m)
    dst = np.empty_like(src)
    same = rng.uniform(size=2 * m) < p_intra
    for i in range(2 * m):
        if same[i]:
            pool = np.nonzero(labels == labels[src[i]])[0]
        else:
            pool = np.nonzero(labels != labels[src[i]])[0]
        dst[i] = pool[rng.integers(0, pool.shape[0])]
    mask = src != dst
    src, dst = src[mask], dst[mask]
    key = dst.astype(np.int64) * n + src
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx][:m], dst[idx][:m]
    x = np.eye(k, dtype=np.float32)[labels] + rng.normal(0, 0.8, (n, k)).astype(np.float32)
    return CSRGraph.from_edges(n, src, dst), x, labels, rng


def train_gnn(model, dims, g, x, labels, train_idx, steps=60, lr=0.05, seed=0):
    params = model.init_layers(jax.random.PRNGKey(seed), dims)
    y = jnp.asarray(labels)
    xj = jnp.asarray(x)
    ti = jnp.asarray(train_idx)

    def loss_fn(ps):
        h = full_forward(model, ps, xj, g)[-1].h
        logits = h[ti]
        return jnp.mean(
            jax.scipy.special.logsumexp(logits, -1) -
            jnp.take_along_axis(logits, y[ti][:, None], 1)[:, 0]
        )

    vg = jax.jit(jax.value_and_grad(loss_fn))
    for _ in range(steps):
        l, grads = vg(params)
        params = jax.tree.map(lambda p, g_: p - lr * g_, params, grads)
    return params


def accuracy(h, labels, idx):
    pred = np.asarray(jnp.argmax(h, -1))[idx]
    return float((pred == labels[idx]).mean())


def run(quick: bool = True):
    n, k = 600, 8
    g, x, labels, rng = make_sbm(n, k, p_intra=0.9, deg=8.0, seed=0)
    train_idx = np.arange(0, n // 2)
    test_idx = np.arange(n // 2, n)
    model = make_model("sage")
    dims = [k, 16, k]
    params = train_gnn(model, dims, g, x, labels, train_idx)

    # stream: new intra-community edges (fresh structure carries signal)
    num_batches, per = (4, 40) if quick else (10, 60)
    batches: List[UpdateBatch] = []
    cur = g
    for _ in range(num_batches):
        ins_s, ins_d = [], []
        while len(ins_s) < per:
            u = int(rng.integers(0, n))
            pool = np.nonzero(labels == labels[u])[0]
            v = int(pool[rng.integers(0, pool.shape[0])])
            if u != v and not cur.has_edge(u, v) and (u, v) not in zip(ins_s, ins_d):
                ins_s.append(u)
                ins_d.append(v)
        b = UpdateBatch(
            ins_src=np.array(ins_s, np.int64), ins_dst=np.array(ins_d, np.int64),
            del_src=np.zeros(0, np.int64), del_dst=np.zeros(0, np.int64),
            ins_weights=np.ones(per, np.float32), ins_etypes=np.zeros(per, np.int32),
        )
        batches.append(b)
        cur = cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                                b.ins_weights, b.ins_etypes)

    # MTEC-Optimal: retrain + recompute on the final graph
    params_opt = train_gnn(model, dims, cur, x, labels, train_idx, seed=1)
    h_opt = full_forward(model, params_opt, jnp.asarray(x), cur)[-1].h
    emit("table4/mtec_optimal_acc", 0, f"{accuracy(h_opt, labels, test_idx):.4f}")

    # MTEC-Period: stale model + stale embeddings (no refresh within window)
    h_stale = full_forward(model, params, jnp.asarray(x), g)[-1].h
    emit("table4/mtec_period_acc", 0, f"{accuracy(h_stale, labels, test_idx):.4f}")

    # RTEC-Inc: frozen model, incremental embeddings
    eng = RTECEngine(model, params, g, jnp.asarray(x))
    for b in batches:
        eng.apply_batch(b)
    emit("table4/rtec_inc_acc", 0, f"{accuracy(eng.embeddings, labels, test_idx):.4f}")

    # RTEC == full-neighbor recomputation (identical semantics)
    h_full = full_forward(model, params, jnp.asarray(x), cur)[-1].h
    mse = float(jnp.mean((eng.embeddings - h_full) ** 2))
    emit("table4/inc_vs_full_mse", 0, f"{mse:.2e}")

    for fanout in (5, 10, 20):
        ns = RTECSample(model, params, g, jnp.asarray(x), fanout=fanout, seed=2)
        for b in batches:
            ns.apply_batch(b)
        emit(f"table4/rtec_ns{fanout}_acc", 0,
             f"{accuracy(ns.embeddings, labels, test_idx):.4f}")
