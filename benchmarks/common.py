"""Shared benchmark helpers: graph/stream setup, method registry, timing,
CSV emission (`name,us_per_call,derived`)."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RTECUER,
    MTECPeriod,
    RTECEngine,
    RTECFull,
    RTECSample,
    make_model,
)
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def setup(
    kind: str = "powerlaw",
    n: int = 2000,
    avg_degree: float = 8.0,
    d: int = 16,
    num_batches: int = 5,
    batch_edges: int = 20,
    delete_frac: float = 0.3,
    seed: int = 0,
):
    g = make_graph(kind, n, avg_degree=avg_degree, seed=seed, weighted=True)
    x, _ = random_features(n, d, seed=seed)
    wl = make_stream(g, num_batches=num_batches, batch_edges=batch_edges,
                     delete_frac=delete_frac, seed=seed + 1)
    return g, x, wl


def make_engine(method: str, model, params, base, x):
    x = jnp.asarray(x)
    if method == "inc":
        return RTECEngine(model, params, base, x)
    if method == "full":
        return RTECFull(model, params, base, x)
    if method == "uer":
        return RTECUER(model, params, base, x)
    if method.startswith("ns"):
        return RTECSample(model, params, base, x, fanout=int(method[2:]))
    if method == "period":
        return MTECPeriod(model, params, base, x, period=5)
    raise ValueError(method)


def run_stream(engine, wl) -> Tuple[float, Dict[str, float]]:
    """Apply all batches; returns (mean wall s/batch, aggregate counters).

    Timing is honest: each batch is synced (``jax.block_until_ready``) at
    the timed boundary so async dispatch can't leak a batch's execution into
    its successor's wall time."""
    agg = {"inc_edges": 0, "full_edges": 0, "vertices": 0,
           "plan_s": 0.0, "exec_s": 0.0, "graph_s": 0.0}
    times = []
    for b in wl.batches:
        t0 = time.perf_counter()
        st = engine.apply_batch(b)
        # sync device-side where the engine exposes its state arrays:
        # ShardedRTECEngine's .embeddings is a full D2H gather + reshape,
        # which would charge an O(N·d) host copy to every timed batch
        sync = (engine._sync_arrays() if hasattr(engine, "_sync_arrays")
                else engine.embeddings)
        jax.block_until_ready(sync)
        times.append(time.perf_counter() - t0)
        agg["inc_edges"] += st.inc_edges
        agg["full_edges"] += st.full_edges
        agg["vertices"] += st.out_vertices
        agg["plan_s"] += st.plan_time_s
        agg["exec_s"] += st.exec_time_s
        agg["graph_s"] += st.graph_time_s
    # min over post-warmup batches: pow-2 capacity buckets retrace on growth,
    # and a 3-batch mean would charge that compile time to the engine
    t = np.min(times[1:]) if len(times) > 1 else times[0]
    return float(t), agg


def run_stream_pipelined(engine, wl) -> float:
    """Plan/execute-overlapped stream application (RTECEngine.apply_stream).

    Returns honest wall seconds per batch over the steady-state tail: the
    first batch is applied separately as warmup (it pays the fused-step
    compile for the stream's shape buckets), then the rest run pipelined."""
    warm, rest = wl.batches[0], wl.batches[1:]
    engine.apply_batch(warm)
    if not rest:
        return 0.0
    ss = engine.apply_stream(rest)
    return ss.wall_s / len(rest)


def gnn_params(model, dims, seed=0):
    return model.init_layers(jax.random.PRNGKey(seed), dims)


def emit_stream_stats(prefix: str, ss, expect_prefetch: int = None,
                      expect_reads: int = None,
                      expect_staleness: int = None) -> None:
    """Emit a StreamStats through its normalized ``as_dict()`` view (the
    single result type, ISSUE 6) as the standard `<prefix>_*` rows:

    * ``<prefix>_stream_wall`` — wall us, ``plan_<v>us`` derived;
    * ``<prefix>_prefetch_hits`` / ``<prefix>_staged_bytes`` — the overlap
      counters (only when ``expect_prefetch`` is given: structural
      expectation for the CI exact gate);
    * ``<prefix>_reads_served`` / ``<prefix>_staleness_batches`` — the
      serving front-end's deterministic read counters (only when
      ``expect_reads`` is given; CI exact gate), plus the non-gated
      ``<prefix>_read_p99`` latency row.
    """
    d = ss.as_dict()
    emit(f"{prefix}_stream_wall", d["wall_s"] * 1e6,
         f"plan_{d['plan_s'] * 1e6:.0f}us")
    if expect_prefetch is not None:
        emit(f"{prefix}_prefetch_hits", float(d["prefetch_hits"]),
             f"expect_{expect_prefetch}")
        emit(f"{prefix}_staged_bytes", float(d["staged_bytes"]),
             f"sync_wait_{d['sync_wait_s'] * 1e6:.0f}us_compute_"
             f"{d['compute_s'] * 1e6:.0f}us")
    if expect_reads is not None:
        emit(f"{prefix}_reads_served", float(d["reads_served"]),
             f"expect_{expect_reads}")
        emit(f"{prefix}_staleness_batches", float(d["staleness_batches"]),
             f"expect_{expect_staleness}")
        emit(f"{prefix}_read_p99", d["read_p99_s"] * 1e6,
             f"p50_{d['read_p50_s'] * 1e6:.0f}us_rejected_"
             f"{d['reads_rejected']}")
