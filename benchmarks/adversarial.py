"""Adversarial-stream policy matrix (ISSUE 7): 3 regimes × {policy, fixed}.

For each :data:`~repro.graph.streaming.ADVERSARIAL_REGIMES` regime this cell
runs the same stream through the device engine four times — the adaptive
:class:`~repro.core.policy.ExecutionPolicy` plus the three forced fixed
modes — and emits, per regime:

* ``adversarial/<regime>/policy_{incremental,chunked,full}_batches`` — the
  adaptive run's per-mode decision counts.  The stream construction is
  deterministic (seeded features, fixed structure), so these gate
  **exactly** (BLOCKING) against the structural expectation embedded in
  the derived column and the committed baseline.
* ``adversarial/<regime>/policy_edges`` — the adaptive run's raw
  edge-work total (``StreamStats.policy_edges``), gated as an absolute
  ceiling (tolerance 0: deterministic).
* ``adversarial/<regime>/policy_cost_vs_best_fixed`` — best fixed mode's
  weighted cost total ÷ the adaptive run's (``StreamStats.policy_cost``),
  in the cost model's edge-work units.  Plans are mode-independent, so
  the adaptive argmin is ≤ every fixed mode by construction: the ratio is
  deterministic and ≥ 1.0; the CI floor 0.91 is the ISSUE's "within
  1.1× of the best fixed mode" acceptance bound.
* ``adversarial/<regime>/policy_wall_vs_best_fixed`` — same ratio in wall
  time.  Wall on a 2-core CI host is noisy and compile-heavy at this
  scale (n=256, 6 batches), so the floor is generous and the exact
  structure is carried by the deterministic counters above instead.

The per-regime expectations (decision counts, edge ceilings) live in
``check_regression.ADVERSARIAL_EXPECTED`` — one table shared by this
emitting cell and the gate's adversarial suites, so the bench and the
gate cannot drift apart.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import jax

from benchmarks.check_regression import ADVERSARIAL_EXPECTED as EXPECTED
from benchmarks.common import emit

MODES = ("incremental", "chunked", "full")


def _run_once(model, wl, x, params, spec) -> tuple:
    """One fresh engine over the whole stream; returns (StreamStats, wall_s).

    Wall is measured around ``apply_stream`` only (construction and the
    base forward pass are identical across modes and excluded).  The
    caller passes one shared ``model`` instance: the fused/chunked/full
    kernels are jitted with the model as a static argument, so sharing it
    is what lets the warmup runs actually warm the timed runs."""
    from repro.core.backend import DeviceBackend, StreamOrchestrator
    from repro.core.policy import make_policy

    be = DeviceBackend(model, params, wl.base, x)
    orch = StreamOrchestrator(be, wl.base, policy=make_policy(spec))
    t0 = time.perf_counter()
    ss = orch.apply_stream(wl.batches)
    jax.block_until_ready(be.sync_arrays())
    return ss, time.perf_counter() - t0


def run_regime(regime: str) -> None:
    from repro.core import make_model
    from repro.graph import make_adversarial_stream
    from repro.graph.generators import random_features

    wl = make_adversarial_stream(regime)
    x, _ = random_features(wl.base.n, 8, seed=0)
    model = make_model("gcn")
    params = model.init_layers(jax.random.PRNGKey(0), [8, 8])

    # warmup pass: populate the jit caches for every execution shape so
    # the timed runs compare steady-state dispatch, not compilation
    for spec in ("adaptive",) + MODES:
        _run_once(model, wl, x, params, spec)

    pol_ss, pol_wall = _run_once(model, wl, x, params, "adaptive")
    pol = pol_ss.as_dict()
    fixed: Dict[str, dict] = {}
    for mode in MODES:
        ss, wall = _run_once(model, wl, x, params, mode)
        d = ss.as_dict()
        d["wall"] = wall
        fixed[mode] = d
        emit(f"adversarial/{regime}/fixed_{mode}_cost", d["policy_cost"],
             f"edges_{d['policy_edges']}")

    exp = EXPECTED[regime]
    for mode in MODES:
        emit(f"adversarial/{regime}/policy_{mode}_batches",
             float(pol[f"policy_{mode}_batches"]), f"expect_{exp[mode]}")
    emit(f"adversarial/{regime}/policy_edges", float(pol["policy_edges"]),
         f"expect_{exp['policy_edges']}")

    # best fixed mode = lowest weighted cost total for this regime; the
    # adaptive per-batch argmin over identical plans can never exceed it
    best_mode = min(MODES, key=lambda m: fixed[m]["policy_cost"])
    cost_ratio = fixed[best_mode]["policy_cost"] / max(pol["policy_cost"], 1e-9)
    emit(f"adversarial/{regime}/policy_cost_vs_best_fixed",
         pol["policy_cost"], f"{cost_ratio:.2f}x")
    best_wall = min(f["wall"] for f in fixed.values())
    emit(f"adversarial/{regime}/policy_wall_vs_best_fixed",
         pol_wall * 1e6, f"{best_wall / max(pol_wall, 1e-9):.2f}x")


def run(regimes: Optional[Sequence[str]] = None) -> None:
    from repro.graph import ADVERSARIAL_REGIMES

    for regime in regimes or ADVERSARIAL_REGIMES:
        run_regime(regime)
