"""Paper Fig. 12 + Table VII sensitivity studies:
  (a,b) batch-size sweep |ΔE| → response time / throughput / speedup;
  (c)   latency-bounded achievable throughput;
  (d)   ODEC query-size sweep;
  (e)   constant-message-only incremental systems (InkStream/Ripple class)
        vs the decoupled engine on a context-dependent model (GCN);
  (VII) layer-count sweep (2 vs 3).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, gnn_params, make_engine, run_stream, setup
from repro.core import RTECEngine, make_model, odec_query
from repro.graph import make_stream


def run(quick: bool = True):
    n = 4000 if quick else 20000
    model = make_model("sage")
    params = gnn_params(model, [16, 16, 16])

    # ---------------- (a,b) |ΔE| sweep ----------------
    sizes = [2, 8, 32, 128] if quick else [2, 8, 32, 128, 512, 2048]
    for be in sizes:
        g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=3, batch_edges=be)
        inc = make_engine("inc", model, params, wl.base, x)
        t_inc, _ = run_stream(inc, wl)
        full = make_engine("full", model, params, wl.base, x)
        t_full, _ = run_stream(full, wl)
        emit(f"fig12a/dE={be}", t_inc * 1e6,
             f"speedup={t_full/t_inc:.1f}x|thpt={be/t_inc:.0f}upd_s")

    # ---------------- (c) latency-bounded throughput ----------------
    g, x, wl0 = setup("powerlaw", n=n, avg_degree=8.0, num_batches=2, batch_edges=8)
    for bound_ms in (50, 200, 1000):
        best = 0
        for be in sizes:
            wl = make_stream(g, num_batches=2, batch_edges=be, delete_frac=0.3, seed=7)
            eng = make_engine("inc", model, params, wl.base, x)
            t, _ = run_stream(eng, wl)
            if t * 1e3 <= bound_ms:
                best = max(best, int(be / t))
        emit(f"fig12c/latency_{bound_ms}ms", 0, f"{best}_upd_per_s")

    # ---------------- (d) ODEC query-size sweep ----------------
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=1, batch_edges=16)
    eng = RTECEngine(model, params, wl.base, jnp.asarray(x))
    rng = np.random.default_rng(0)
    for q in (1, 16, 256, n):
        qs = rng.choice(n, size=min(q, n), replace=False).astype(np.int64)
        t0 = time.perf_counter()
        _, stats = odec_query(eng, wl.batches[0], qs)
        dt = time.perf_counter() - t0
        emit(f"fig12d/odec_q{q}", dt * 1e6, f"edges={stats.edges_processed}")

    # ---------------- (e) constant-message systems ----------------
    # InkStream/Ripple-class engines support only constant edge messages —
    # for GCN (degree-coupled messages) they must fall back to full-neighbor
    # recomputation; the decoupled engine stays incremental.
    gcn = make_model("gcn")
    gparams = gnn_params(gcn, [16, 16, 16])
    g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=3, batch_edges=16)
    ours = make_engine("inc", gcn, gparams, wl.base, x)
    t_ours, _ = run_stream(ours, wl)
    fallback = make_engine("full", gcn, gparams, wl.base, x)  # their GCN path
    t_fb, _ = run_stream(fallback, wl)
    emit("fig12e/gcn_ours_vs_constmsg_system", t_ours * 1e6,
         f"{t_fb/t_ours:.1f}x_speedup")
    gin = make_model("gin")
    iparams = gnn_params(gin, [16, 16, 16])
    ours_gin = make_engine("inc", gin, iparams, wl.base, x)
    t_gin, _ = run_stream(ours_gin, wl)
    emit("fig12e/gin_both_incremental", t_gin * 1e6, "parity_model")

    # ---------------- (VII) layers 2 vs 3 ----------------
    for L in (2, 3):
        p = gnn_params(model, [16] * (L + 1))
        g, x, wl = setup("powerlaw", n=n, avg_degree=8.0, num_batches=3, batch_edges=16)
        inc = make_engine("inc", model, p, wl.base, x)
        t_i, _ = run_stream(inc, wl)
        full = make_engine("full", model, p, wl.base, x)
        t_f, _ = run_stream(full, wl)
        emit(f"table7/L{L}", t_i * 1e6, f"speedup_vs_full={t_f/t_i:.1f}x")
