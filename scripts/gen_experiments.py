"""Generate EXPERIMENTS.md from the dry-run JSONs + curated §Perf log.

    PYTHONPATH=src python scripts/gen_experiments.py
"""
from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "qwen2.5-3b", "granite-3-2b", "llama3.2-1b", "minicpm-2b", "xlstm-1.3b",
    "seamless-m4t-large-v2", "pixtral-12b", "hymba-1.5b", "qwen3-moe-30b-a3b",
    "moonshot-v1-16b-a3b", "gnn_rtec_inc", "gnn_rtec_inc_compact", "gnn_full_layer",
]


def load(mode):
    cells = {}
    d = DRY / mode
    if not d.exists():
        return cells
    for f in sorted(d.glob("*.json")):
        c = json.loads(f.read_text())
        key = (c["arch"], c.get("shape", ""), c["mesh"])
        cells[key] = c
    return cells


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | lower | compile | bytes/device (args+temp) | collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER + [""]:
            for mesh in ("16x16", "2x16x16"):
                c = cells.get((a, s, mesh)) or (
                    cells.get((a, next((k[1] for k in cells if k[0] == a), ""), mesh))
                    if s == "" and a.startswith("gnn") else None
                )
                if c is None:
                    continue
                if "skipped" in c:
                    lines.append(f"| {a} | {s} | {mesh} | — | — | skipped: {c['skipped'][:48]} | — |")
                    continue
                m = c["memory_analysis"]
                gb = (m.get("argument_bytes_per_device", 0) + m.get("temp_bytes_per_device", 0)) / 1e9
                counts = c["hlo_per_device"].get("collective_counts", {})
                cc = " ".join(f"{k}:{v}" for k, v in sorted(counts.items()))
                lines.append(
                    f"| {a} | {c.get('shape','')} | {mesh} | {c.get('lower_s','—')}s | "
                    f"{c.get('compile_s','—')}s | {gb:.2f} GB | {cc[:60]} |"
                )
            if s == "":
                break
    return "\n".join(lines)


def roofline_table(cells, mode):
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant | MODEL/HLO flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER + [""]:
            for mesh in ("16x16",) if mode != "both" else ("16x16", "2x16x16"):
                key = (a, s, mesh)
                c = cells.get(key)
                if c is None and s == "" and a.startswith("gnn"):
                    c = next((v for k, v in cells.items() if k[0] == a and k[2] == mesh), None)
                if c is None:
                    continue
                if "skipped" in c:
                    lines.append(f"| {a} | {s} | {mesh} | — | — | — | skipped | — |")
                    continue
                r = c["roofline"]
                uf = c.get("model_flops", {}).get("useful_fraction")
                ufs = f"{uf:.2f}" if uf is not None else "—"
                lines.append(
                    f"| {a} | {c.get('shape','')} | {mesh} | {fmt_s(r['compute_s'])} | "
                    f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                    f"**{r['dominant']}** | {ufs} |"
                )
            if s == "":
                break
    return "\n".join(lines)


def main():
    opt = load("opt")
    base = load("baseline")
    perf_log = (ROOT / "scripts" / "perf_log.md").read_text()
    repro_notes = (ROOT / "scripts" / "repro_notes.md").read_text()

    out = f"""# EXPERIMENTS

All numbers produced in this container (CPU host; TPU v5e is the *target*):
dry-run = ``.lower().compile()`` against the production meshes with 512
forced host devices; roofline terms derived from the compiled HLO
(``src/repro/launch/hlo_analysis.py`` — see DESIGN.md §10 for the traffic
model).  Hardware constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI per chip.

Reproduce:
```
PYTHONPATH=src python -m repro.launch.dryrun --all --mode opt      # LM cells
PYTHONPATH=src python -m repro.launch.gnn_dryrun --mode opt        # GNN cells
PYTHONPATH=src python scripts/gen_experiments.py                   # this file
PYTHONPATH=src python -m benchmarks.run                            # paper artifacts
```

{repro_notes}

## Memory-fit note

``memory_analysis`` numbers come from XLA:CPU's buffer assignment of the
512-way-partitioned program.  Serving/decode cells and the compact GNN cell
fit v5e HBM (<16 GB/device) outright.  Train/prefill cells report larger
*temp* figures because the CPU pipeline (a) materializes attention
score/prob buffers that the TPU deployment streams through VMEM via the
Pallas flash kernel (we bound them with 2k-query chunking, e.g. minicpm
prefill 657 GB → 68 GB, but CPU buffer assignment still keeps per-layer
buffers live that TPU's assigner aliases), and (b) does not alias
loop-carried remat buffers.  The deployment working set is
args (params+opt, exact per-device bytes in the table) + layer residuals
(L·B_local·S·d_model·2B ≈ 0.3–2.7 GB across the train cells) + the flash
working set — within 16 GB for every cell; the flash-adjusted HBM-traffic
column in §Roofline reflects the same model.

## §Dry-run — multi-pod lower+compile (mode=opt)

Every (architecture × shape) cell compiles for BOTH the single-pod 16×16
mesh (256 chips) and the 2×16×16 multi-pod mesh (512 chips; "pod" axis
shards DP).  ``long_500k`` runs for the sub-quadratic archs (xlstm, hymba)
and is skipped for pure full-attention archs per the assignment.
Serving cells shard params TP-only; training cells FSDP(+pod)×TP with
ZeRO-1 optimizer sharding.  GNN cells: the paper's technique at
V=67M/E=1B scale (see §Perf).

{dryrun_table(opt)}

## §Roofline — per-device terms, single-pod mesh (mode=opt)

`compute = HLO_FLOPs/(chips×197e12)`, `memory = HBM_bytes/(chips×819e9)`
(train/prefill memory uses the flash-adjusted bytes — attention matrices
stream through VMEM on TPU), `collective = wire_bytes/(chips×50e9)` with
ring-algorithm factors per op.  `MODEL/HLO` = 6·N_active·D ÷ total compiled
FLOPs (useful-compute fraction; <1 ⇔ remat/attention/capacity overheads).

{roofline_table(opt, "single")}

### Baseline (paper-faithful naive port, no activation-sharding constraints)

The `baseline` mode lowers the same programs WITHOUT the explicit activation
sharding constraints — XLA propagation alone — and was captured at
iteration 0 of the code (before grouped-GQA decode and chunked-prefill
attention landed), i.e. it is the honest "naive JAX port" starting point
of §Perf.

{roofline_table(base, "single")}

{perf_log}
"""
    (ROOT / "EXPERIMENTS.md").write_text(out)
    print(f"wrote EXPERIMENTS.md ({len(out)} chars) from {len(opt)}+{len(base)} cells")


if __name__ == "__main__":
    main()
