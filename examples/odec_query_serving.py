"""On-demand embedding computation (ODEC, paper §V-D): serve point queries
over a streaming graph with bounded latency, comparing the query-cone
restricted computation against full commits.

    PYTHONPATH=src python examples/odec_query_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RTECEngine, make_model, odec_query
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features

N = 5000
graph = make_graph("powerlaw", n=N, avg_degree=8, seed=4)
x, _ = random_features(N, d=16, seed=4)
stream = make_stream(graph, num_batches=4, batch_edges=30, seed=5)

model = make_model("gcn")
params = model.init_layers(jax.random.PRNGKey(3), [16, 16, 16])
engine = RTECEngine(model, params, stream.base, jnp.asarray(x))

rng = np.random.default_rng(0)
for qsize in (1, 10, 100, 1000):
    b = stream.batches[0]
    q = rng.choice(N, size=qsize, replace=False).astype(np.int64)
    t0 = time.perf_counter()
    emb, stats = odec_query(engine, b, q)
    dt = (time.perf_counter() - t0) * 1e3
    print(f"|V_Q|={qsize:5d}: {dt:7.1f}ms, edges={stats.edges_processed:6d}, "
          f"vertices={stats.out_vertices}")
