"""End-to-end serving scenario: real-time fraud scoring on a transaction
stream (the paper's motivating application).

A GraphSAGE encoder is trained on the historical transaction graph; at
serving time, transaction batches arrive as edge insertions and the
incremental engine refreshes account embeddings, which a scoring head
converts to fraud probabilities.  ODEC answers point queries ("score these
accounts NOW") from the query cone without committing state.

    PYTHONPATH=src python examples/streaming_fraud_detection.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RTECEngine, make_model, odec_query
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features

N = 3000
graph = make_graph("powerlaw", n=N, avg_degree=10, seed=1)
x, _ = random_features(N, d=16, seed=1)
stream = make_stream(graph, num_batches=8, batch_edges=25, delete_frac=0.1, seed=2)

model = make_model("sage")
params = model.init_layers(jax.random.PRNGKey(1), [16, 32, 16])
w_score = jax.random.normal(jax.random.PRNGKey(2), (16, 1)) * 0.3

engine = RTECEngine(model, params, stream.base, jnp.asarray(x))
score = jax.jit(lambda h: jax.nn.sigmoid(h @ w_score)[:, 0])

for i, batch in enumerate(stream.batches):
    # point query BEFORE commit: score the accounts touched by this batch
    accounts = batch.updated_vertices()[:8]
    t0 = time.perf_counter()
    emb_q, stats = odec_query(engine, batch, accounts)
    q_ms = (time.perf_counter() - t0) * 1e3
    risk = score(emb_q)
    flagged = accounts[np.asarray(risk) > 0.5]
    # asynchronous state commit
    st = engine.apply_batch(batch)
    print(
        f"batch {i}: ODEC answered {len(accounts)} queries in {q_ms:5.1f}ms "
        f"({stats.edges_processed} edges) | commit touched "
        f"{st.out_vertices} vertices | flagged={list(flagged)[:4]}"
    )

print("final embedding norm:", float(jnp.linalg.norm(engine.embeddings)))
