"""Train a ~40M-param llama-family model for 200 steps with the
full production substrate (AdamW+WSD, checkpointing, fault-tolerant runner).

    PYTHONPATH=src python examples/lm_pretrain_smoke.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import get_arch
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

# ~40M params (CPU-friendly): 8 layers, d=512, llama3-style;
# scale d_model/num_layers up for the ~100M+ regime on real hardware
cfg = dataclasses.replace(
    get_arch("llama3.2-1b"),
    num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=32000, remat=False,
    param_dtype="float32", compute_dtype="float32",
)
print(f"params ≈ {cfg.param_count()/1e6:.0f}M")
t = Trainer(
    cfg,
    TrainConfig(steps=args.steps, batch=4, seq_len=128, log_every=20,
                checkpoint_dir="/tmp/repro_lm_ckpt", checkpoint_every=100),
    OptConfig(peak_lr=1e-3, warmup_steps=20, stable_steps=args.steps, decay_steps=20),
)
out = t.train()
print(out)
