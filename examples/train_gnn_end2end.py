"""End-to-end driver: train a GNN encoder (a few hundred steps) on a
community-structured graph, then serve a streaming update workload with the
incremental engine and track accuracy vs periodic recomputation.

    PYTHONPATH=src python examples/train_gnn_end2end.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # for benchmarks/

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.table4_accuracy import accuracy, make_sbm, train_gnn
from repro.core import MTECPeriod, RTECEngine, full_forward, make_model
from repro.graph.streaming import UpdateBatch

n, k = 800, 8
graph, x, labels, rng = make_sbm(n, k, p_intra=0.9, deg=8.0, seed=3)
train_idx = np.arange(0, n // 2)
test_idx = np.arange(n // 2, n)

model = make_model("sage")
print("training GraphSAGE (300 steps)...")
params = train_gnn(model, [k, 32, k], graph, x, labels, train_idx, steps=300, lr=0.03)
h0 = full_forward(model, params, jnp.asarray(x), graph)[-1].h
print(f"base accuracy: {accuracy(h0, labels, test_idx):.3f}")

inc = RTECEngine(model, params, graph, jnp.asarray(x))
period = MTECPeriod(model, params, graph, jnp.asarray(x), period=10)

cur = graph
for i in range(6):
    ins_s, ins_d = [], []
    while len(ins_s) < 30:
        u = int(rng.integers(0, n))
        pool = np.nonzero(labels == labels[u])[0]
        v = int(pool[rng.integers(0, pool.shape[0])])
        if u != v and not cur.has_edge(u, v) and (u, v) not in zip(ins_s, ins_d):
            ins_s.append(u); ins_d.append(v)
    b = UpdateBatch(
        ins_src=np.array(ins_s, np.int64), ins_dst=np.array(ins_d, np.int64),
        del_src=np.zeros(0, np.int64), del_dst=np.zeros(0, np.int64),
        ins_weights=np.ones(30, np.float32), ins_etypes=np.zeros(30, np.int32))
    cur = cur.apply_updates(b.ins_src, b.ins_dst, b.del_src, b.del_dst,
                            b.ins_weights, b.ins_etypes)
    inc.apply_batch(b)
    period.apply_batch(b)
    print(f"batch {i}: inc_acc={accuracy(inc.embeddings, labels, test_idx):.3f} "
          f"period_acc={accuracy(period.embeddings, labels, test_idx):.3f}")
