"""Quickstart: incremental RTEC on a streaming graph in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RTECEngine, RTECFull, make_model
from repro.graph import make_graph, make_stream
from repro.graph.generators import random_features

# 1. a streaming graph: power-law base + insert/delete batches
graph = make_graph("powerlaw", n=2000, avg_degree=8, seed=0)
x, _ = random_features(2000, d=32, seed=0)
stream = make_stream(graph, num_batches=10, batch_edges=20, delete_frac=0.3)

# 2. a GNN from the Table-II zoo, decoupled for incremental processing
model = make_model("gat", heads=2)  # constrained model — hardest case
params = model.init_layers(jax.random.PRNGKey(0), [32, 32, 32])

# 3. the incremental engine vs naive full-neighbor recomputation
inc = RTECEngine(model, params, stream.base, jnp.asarray(x))
full = RTECFull(model, params, stream.base, jnp.asarray(x))

for i, batch in enumerate(stream.batches):
    s_inc = inc.apply_batch(batch)
    s_full = full.apply_batch(batch)
    print(
        f"batch {i}: inc {s_inc.edges_processed:5d} edges in {s_inc.exec_time_s*1e3:6.1f}ms | "
        f"full {s_full.edges_processed:6d} edges in {s_full.exec_time_s*1e3:6.1f}ms"
    )

# 4. equivalence: incremental == full-neighbor recomputation (Theorem 1)
err = float(jnp.abs(inc.embeddings - full.embeddings).max())
print(f"max |inc - full| = {err:.2e}  (Theorem-1 equivalence)")
assert err < 1e-3
